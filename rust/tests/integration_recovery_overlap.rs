//! Parallel-vs-serial recovery equivalence: the fanned-out recovery
//! control plane (`RecoveryPolicy::serial_recovery = false`, the default)
//! must produce the *same engine state* as the serialized baseline — same
//! `RecoveryReport`/`ReviveReport` counts, identical post-recovery token
//! streams — and a survivor that hangs mid-recompile must surface as a
//! bounded deadline error that leaves the engine paused (instance-fatal
//! per the `recover` contract), never a deadlock.
//!
//! Needs `make artifacts` (skipped loudly otherwise), like the other
//! integration suites.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use revivemoe::cluster::{FailureBehavior, FaultLevel};
use revivemoe::config::DeploymentConfig;
use revivemoe::engine::Engine;
use revivemoe::recovery::{RecoveryReport, ReviveMoE, ReviveReport};
use revivemoe::scheduler::{SeqId, Token};
use revivemoe::workload;

fn ready() -> bool {
    Path::new("artifacts/hlo/manifest.json").exists()
}

fn inject(engine: &mut Engine, device: usize, behavior: FailureBehavior) {
    engine.executors[&device].handle.set_failed(behavior);
    engine
        .plugin
        .post_fault(device, FaultLevel::L6, behavior, "test-injected");
}

/// Boot `cfg`, put traffic on it, fail `device`, recover (optionally
/// revive the device afterwards), and serve everything to completion.
/// Returns the recovery report, the revival report if requested, and
/// every request's decoded stream keyed by sequence id — the equivalence
/// surface the serial/overlapped comparison asserts on.
fn run_scenario(
    mut cfg: DeploymentConfig,
    serial: bool,
    device: usize,
    revive_after: bool,
) -> (RecoveryReport, Option<ReviveReport>, BTreeMap<SeqId, Vec<Token>>) {
    cfg.recovery.serial_recovery = serial;
    let (mut engine, _bd) = Engine::boot(cfg).expect("boot");
    for r in workload::gen_mixed(12, 19).expect("workload") {
        engine.submit(r).expect("submit");
    }
    let mut done = Vec::new();
    for _ in 0..3 {
        done.extend(engine.step().expect("pre-failure step"));
    }
    inject(&mut engine, device, FailureBehavior::Erroring);
    let ann = engine.detect_failure().expect("must detect");
    let report = ReviveMoE::recover(&mut engine, &ann).expect("recover");
    let revive_report = if revive_after {
        for _ in 0..2 {
            done.extend(engine.step().expect("post-recovery step"));
        }
        Some(ReviveMoE::revive(&mut engine, device).expect("revive"))
    } else {
        None
    };
    done.extend(engine.run_to_completion(500).expect("serve"));
    engine.shutdown();
    let streams: BTreeMap<SeqId, Vec<Token>> =
        done.into_iter().map(|c| (c.seq_id, c.output)).collect();
    assert_eq!(streams.len(), 12, "every request must complete");
    (report, revive_report, streams)
}

fn assert_reports_equal(serial: &RecoveryReport, overlap: &RecoveryReport) {
    assert_eq!(serial.role, overlap.role);
    assert_eq!(serial.moe_recovery, overlap.moe_recovery);
    assert_eq!(serial.migrated_sequences, overlap.migrated_sequences);
    assert_eq!(serial.undone_block_ops, overlap.undone_block_ops);
    assert_eq!(serial.requeued_unprefilled, overlap.requeued_unprefilled);
    assert_eq!(serial.recompiled_graphs, overlap.recompiled_graphs);
    assert_eq!(serial.masked_experts, overlap.masked_experts);
    assert_eq!(serial.switched_device, overlap.switched_device);
}

#[test]
fn attention_failure_parallel_matches_serial() {
    if !ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let cfg = DeploymentConfig::disaggregated_default("artifacts");
    let (rs, _, streams_s) = run_scenario(cfg.clone(), true, 2, false);
    let (rp, _, streams_p) = run_scenario(cfg, false, 2, false);
    assert_reports_equal(&rs, &rp);
    assert!(rp.migrated_sequences > 0, "the failed DP rank had work to migrate");
    assert_eq!(
        streams_s, streams_p,
        "overlapped recovery diverged from the serial baseline"
    );
}

#[test]
fn role_switch_and_revive_parallel_match_serial() {
    if !ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    // redundancy off + missing-experts forbidden forces the role switch —
    // the case whose Generator weight reload the overlapped path keeps in
    // flight behind XCCL recreation and the survivor recompiles
    let mut cfg = DeploymentConfig::disaggregated_default("artifacts");
    cfg.redundant_per_rank = 0;
    cfg.recovery.allow_missing_experts = false;
    let (rs, vs, streams_s) = run_scenario(cfg.clone(), true, 7, true);
    let (rp, vp, streams_p) = run_scenario(cfg, false, 7, true);
    assert_reports_equal(&rs, &rp);
    assert!(rs.switched_device.is_some(), "a DP rank must have switched");
    let (vs, vp) = (vs.unwrap(), vp.unwrap());
    assert_eq!(vs.restored_moe_rank, vp.restored_moe_rank);
    assert_eq!(vs.joined_attention, vp.joined_attention);
    assert_eq!(vs.restored_dense_groups, vp.restored_dense_groups);
    assert_eq!(vs.recompiled_graphs, vp.recompiled_graphs);
    assert!(vp.joined_attention, "the revived device restores the consumed DP width");
    assert_eq!(
        streams_s, streams_p,
        "overlapped role-switch/revival diverged from the serial baseline"
    );
}

#[test]
fn wall_accounting_bounded_by_work_on_both_paths() {
    if !ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let cfg = DeploymentConfig::disaggregated_default("artifacts");
    for serial in [true, false] {
        let (report, _, _) = run_scenario(cfg.clone(), serial, 2, false);
        // wall never exceeds work by more than scheduling noise: the work
        // sums count every rank's compile/read time, the wall only the
        // critical path
        let work = report.total().as_secs_f64();
        let wall = report.wall().as_secs_f64();
        assert!(wall > 0.0, "wall accounting must be populated (serial={serial})");
        assert!(
            wall <= work * 1.5 + 0.25,
            "wall {wall:.3}s inconsistent with work {work:.3}s (serial={serial})"
        );
    }
}

#[test]
fn hung_survivor_mid_recompile_times_out_and_leaves_engine_paused() {
    if !ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let (mut engine, _bd) =
        Engine::boot(DeploymentConfig::disaggregated_default("artifacts")).expect("boot");
    for r in workload::gen_mixed(8, 23).expect("workload") {
        engine.submit(r).expect("submit");
    }
    engine.step().expect("healthy step");

    // fail an attention rank (the fault recovery is for)...
    inject(&mut engine, 2, FailureBehavior::Erroring);
    let ann = engine.detect_failure().expect("must detect");
    // ...then hang a survivor WITHOUT any annotation: the recompile
    // fan-out hits it mid-sweep. Shorten every per-command deadline so
    // the test is fast (correctness, not the constant, is what we assert).
    for ex in engine.executors.values_mut() {
        ex.handle.cmd_timeout = Duration::from_millis(300);
    }
    engine.executors[&3].handle.set_failed(FailureBehavior::Hung);

    let t0 = Instant::now();
    let err = ReviveMoE::recover(&mut engine, &ann)
        .expect_err("a hung survivor must fail the pass, not wedge it");
    let elapsed = t0.elapsed();
    assert!(
        err.to_string().contains("timed out"),
        "expected a deadline error, got: {err}"
    );
    assert!(
        elapsed < Duration::from_secs(30),
        "timeout must be deadline-bounded, took {elapsed:?}"
    );
    assert!(
        engine.serving_blocked(),
        "a failed recovery pass is instance-fatal: the quarantine must stay in place"
    );
    assert!(!engine.recovering, "the re-entrancy guard must be released on error");
    engine.shutdown();
}
