//! Online fault-scenario serving integration (acceptance criteria of the
//! serve subsystem):
//!
//! 1. a seeded scenario with one mid-decode fault is **deterministic**
//!    across two runs — identical token streams per arrival and an
//!    identical tick-stamped event ordering;
//! 2. a **cascading two-fault** scenario (the second device dies while the
//!    first recovery is pending) completes with every surviving sequence
//!    finishing and no panic/deadlock — recoveries run sequentially;
//! 3. a fault-then-revive scenario brings the repaired device back into
//!    the live instance with weight integrity restored;
//! 4. the reinit baseline serves the same scenario end-to-end, restarting
//!    outstanding requests instead of migrating them.
//!
//! Needs `make artifacts` (skipped loudly otherwise), like the other
//! integration suites.

mod common;

use common::{assert_replay_identical, default_cfg, ready, run_with};
use revivemoe::engine::Engine;
use revivemoe::scenario::Scenario;
use revivemoe::serve::{run_scenario, RecoveryStrategy, ServeReport};

fn run(scenario: &Scenario, strategy: RecoveryStrategy) -> ServeReport {
    run_with(default_cfg(), scenario, strategy)
}

#[test]
fn single_fault_scenario_is_deterministic() {
    if !ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let scenario = Scenario::single_fault(21).requests(20);
    let a = run(&scenario, RecoveryStrategy::ReviveMoE);
    let b = run(&scenario, RecoveryStrategy::ReviveMoE);

    // the fault fired and was recovered in place
    assert_eq!(a.recoveries.len(), 1, "exactly one recovery: {:?}", a.recoveries);
    assert_eq!(a.recoveries[0].kind, "revivemoe");
    assert_eq!(a.incomplete, 0, "every request finishes");
    assert_eq!(a.completed.len(), a.submitted);

    // determinism surface: token streams, event ordering, recovery records
    assert_replay_identical(&a, &b);
}

#[test]
fn cascading_double_fault_completes_sequentially() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let scenario = Scenario::cascade(33).requests(20);
    let report = run(&scenario, RecoveryStrategy::ReviveMoE);

    // both faults recovered, one after the other, never nested
    assert_eq!(report.recoveries.len(), 2, "two recoveries: {:?}", report.recoveries);
    assert!(report.recoveries.iter().all(|r| r.kind == "revivemoe"));
    assert_eq!(
        report.recoveries[0].tick, report.recoveries[1].tick,
        "second fault was already posted when the first recovery ran"
    );
    assert_eq!(report.recoveries[0].device, 5, "MoE fault handled first (older event)");
    assert_eq!(report.recoveries[1].device, 2);

    // all surviving sequences finish; nothing wedges
    assert_eq!(report.incomplete, 0, "no request may be stranded by the cascade");
    assert_eq!(report.completed.len(), report.submitted);
    for c in &report.completed {
        assert!(!c.output.is_empty(), "request {} produced no tokens", c.arrival);
    }
    // cascade determinism holds too
    let again = run(&scenario, RecoveryStrategy::ReviveMoE);
    assert_replay_identical(&report, &again);
}

#[test]
fn fault_then_revive_restores_the_device() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let scenario = Scenario::fault_then_revive(45).requests(20);
    let (engine, _bd) = Engine::boot(default_cfg()).expect("boot");
    let (engine, report) = run_scenario(engine, &scenario, RecoveryStrategy::ReviveMoE)
        .expect("serve");

    assert_eq!(report.incomplete, 0);
    let kinds: Vec<&str> = report.recoveries.iter().map(|r| r.kind.as_str()).collect();
    assert_eq!(kinds, vec!["revivemoe", "revive"], "recovery then revival");

    // the revived device is a live executor again with its MoE rank back
    assert!(engine.executors.contains_key(&5), "device 5 rejoined");
    let mr = engine.moe_order.iter().position(|&d| d == 5).expect("rank mapping kept");
    assert!(engine.expert_map.is_alive(mr), "its expert rank is alive again");
    // weight integrity is whole: nothing masked at the gate
    assert!(engine.expert_map.missing_experts().is_empty());
    assert!(engine.expert_map.gate_mask().iter().all(|&m| m == 0.0));
    engine.expert_map.audit().expect("placement consistent after revive");
    engine.shutdown();
}

#[test]
fn reinit_baseline_serves_by_restarting_requests() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let scenario = Scenario::single_fault(57).requests(16);
    let report = run(&scenario, RecoveryStrategy::BaselineReinit);

    assert_eq!(report.recoveries.len(), 1);
    assert_eq!(report.recoveries[0].kind, "reinit");
    assert_eq!(report.incomplete, 0, "the reborn instance finishes everything");
    assert_eq!(report.completed.len(), report.submitted);
    // whatever was in flight at the fault restarted from scratch
    assert!(
        report.stats.requests_restarted > 0,
        "a mid-stream reinit must restart outstanding requests"
    );
    assert!(report.completed.iter().any(|c| c.restarts > 0));
    // and no sequence migrated — that is the ReviveMoE-only mechanism
    assert!(report.completed.iter().all(|c| c.migrations == 0));
}
