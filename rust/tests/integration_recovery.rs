//! End-to-end recovery integration: every §3.4 recovery option plus the
//! baseline reinitialization, exercised against live deployments with
//! requests in flight. Requires `make artifacts`.

use std::path::Path;

use revivemoe::cluster::{FailureBehavior, FaultLevel};
use revivemoe::config::{DeploymentConfig, RecompileScope};
use revivemoe::engine::Engine;
use revivemoe::recovery::{baseline_reinit, MoeRecoveryKind, ReviveMoE};
use revivemoe::workload;

fn ready() -> bool {
    Path::new("artifacts/hlo/manifest.json").exists()
}

fn boot(cfg: DeploymentConfig) -> Engine {
    Engine::boot(cfg).expect("boot").0
}

fn inject(engine: &mut Engine, device: usize, behavior: FailureBehavior) {
    engine.executors[&device].handle.set_failed(behavior);
    engine
        .plugin
        .post_fault(device, FaultLevel::L6, behavior, "test-injected");
}

fn serve_some(
    engine: &mut Engine,
    n: usize,
    seed: u64,
) -> Vec<revivemoe::engine::Completion> {
    for r in workload::gen_mixed(n, seed).unwrap() {
        engine.submit(r).unwrap();
    }
    let mut done = Vec::new();
    for _ in 0..3 {
        done.extend(engine.step().unwrap());
    }
    done
}

#[test]
fn attention_failure_migrates_and_completes() {
    if !ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let mut engine = boot(DeploymentConfig::disaggregated_default("artifacts"));
    let early = serve_some(&mut engine, 16, 5);
    let before_pending = engine.pending();
    assert!(before_pending > 0);

    inject(&mut engine, 2, FailureBehavior::Erroring);
    let ann = engine.detect_failure().expect("must detect");
    assert_eq!(ann.device, 2);
    let report = ReviveMoE::recover(&mut engine, &ann).unwrap();
    assert_eq!(report.role, "attention");
    assert!(report.moe_recovery.is_none());
    assert!(!engine.attn_order.contains(&2));
    assert_eq!(engine.attn_order.len(), 3);

    // everything still completes, and migrated sequences carried their
    // decoded prefix along (partial recomputation §3.2)
    let done = engine.run_to_completion(500).unwrap();
    assert_eq!(early.len() + done.len(), 16);
    assert!(done.iter().any(|c| c.migrations > 0), "someone migrated");
    for c in &done {
        assert!(!c.output.is_empty());
    }
    engine.shutdown();
}

#[test]
fn moe_failure_redundant_experts_no_reload() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let mut cfg = DeploymentConfig::disaggregated_default("artifacts");
    cfg.redundant_per_rank = 8; // full shifted copy -> any failure covered
    let mut engine = boot(cfg);
    let early = serve_some(&mut engine, 12, 9);

    inject(&mut engine, 5, FailureBehavior::Erroring);
    let ann = engine.detect_failure().unwrap();
    let report = ReviveMoE::recover(&mut engine, &ann).unwrap();
    assert_eq!(report.moe_recovery, Some(MoeRecoveryKind::RedundantExperts));
    assert!(report.masked_experts.is_empty());
    assert!(report.switched_device.is_none());
    // no gate masking: all experts still served
    assert!(engine.expert_map.gate_mask().iter().all(|&m| m == 0.0));

    let done = engine.run_to_completion(500).unwrap();
    assert_eq!(early.len() + done.len(), 12);
    engine.shutdown();
}

#[test]
fn moe_failure_missing_experts_masks_gate() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let mut cfg = DeploymentConfig::disaggregated_default("artifacts");
    cfg.redundant_per_rank = 0;
    cfg.recovery.allow_role_switch = false;
    let mut engine = boot(cfg);
    let early = serve_some(&mut engine, 12, 13);

    inject(&mut engine, 6, FailureBehavior::Erroring);
    let ann = engine.detect_failure().unwrap();
    let report = ReviveMoE::recover(&mut engine, &ann).unwrap();
    assert_eq!(report.moe_recovery, Some(MoeRecoveryKind::MissingExperts));
    // MoE rank 2 (device 6) hosts experts 16..24 with no redundancy
    assert_eq!(report.masked_experts, (16..24).collect::<Vec<_>>());
    let mask = engine.expert_map.gate_mask();
    for e in 16..24 {
        assert!(mask[e] < 0.0);
    }

    let done = engine.run_to_completion(500).unwrap();
    assert_eq!(early.len() + done.len(), 12, "inference continues with degraded experts");
    engine.shutdown();
}

#[test]
fn moe_failure_role_switch_reloads_from_disk() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let mut cfg = DeploymentConfig::disaggregated_default("artifacts");
    cfg.redundant_per_rank = 0;
    cfg.recovery.allow_missing_experts = false; // force the switch
    let mut engine = boot(cfg);
    let early = serve_some(&mut engine, 12, 17);

    inject(&mut engine, 7, FailureBehavior::Erroring);
    let ann = engine.detect_failure().unwrap();
    let report = ReviveMoE::recover(&mut engine, &ann).unwrap();
    assert_eq!(report.moe_recovery, Some(MoeRecoveryKind::RoleSwitch));
    let victim = report.switched_device.expect("a DP rank switched");
    assert!(!engine.attn_order.contains(&victim));
    assert_eq!(engine.attn_order.len(), 3, "one DP rank consumed");
    assert_eq!(engine.moe_order[3], victim, "victim took the failed MoE rank");
    // weight integrity restored: nothing masked
    assert!(engine.expert_map.missing_experts().is_empty());
    // Generator time (disk reload) must be visible in the breakdown
    assert!(
        report.breakdown.get(revivemoe::metrics::Category::Generator)
            > std::time::Duration::ZERO
    );

    let done = engine.run_to_completion(500).unwrap();
    assert_eq!(early.len() + done.len(), 12);
    engine.shutdown();
}

#[test]
fn hung_device_detected_by_heartbeat_and_recovered() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let mut engine = boot(DeploymentConfig::disaggregated_default("artifacts"));
    let early = serve_some(&mut engine, 8, 23);
    // hang WITHOUT posting an annotation: only the heartbeat can see this
    engine.executors[&4].handle.set_failed(FailureBehavior::Hung);
    let ann = engine.detect_failure().expect("heartbeat must detect the hang");
    assert_eq!(ann.device, 4);
    assert_eq!(ann.error_type, "heartbeat-timeout");
    let report = ReviveMoE::recover(&mut engine, &ann).unwrap();
    assert_eq!(report.role, "moe");
    let done = engine.run_to_completion(500).unwrap();
    assert_eq!(early.len() + done.len(), 8);
    engine.shutdown();
}

#[test]
fn failure_mid_step_rolls_back_block_tables() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let mut engine = boot(DeploymentConfig::disaggregated_default("artifacts"));
    for r in workload::gen_mixed(8, 31).unwrap() {
        engine.submit(r).unwrap();
    }
    let mut early = engine.step().unwrap(); // prefills + first decode commit
    // kill a MoE device, then drive a step INTO the failure: the step
    // aborts mid-flight, leaving uncommitted block ops in the undo logs
    inject(&mut engine, 5, FailureBehavior::Erroring);
    let err = engine.step();
    assert!(err.is_err(), "step must fail against a dead expert rank");
    let ann = engine.detect_failure().unwrap();
    let report = ReviveMoE::recover(&mut engine, &ann).unwrap();
    assert!(
        report.undone_block_ops > 0,
        "mid-step failure must trigger log-based undo (§3.3)"
    );
    // block tables are consistent again and serving continues to completion
    early.extend(engine.run_to_completion(500).unwrap());
    assert_eq!(early.len(), 8);
    engine.shutdown();
}

#[test]
fn collocated_failure_recovers() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let mut cfg = DeploymentConfig::collocated_default("artifacts");
    cfg.redundant_per_rank = 4; // full coverage for 8 ranks x 4 primaries
    let mut engine = boot(cfg);
    let early = serve_some(&mut engine, 12, 37);
    inject(&mut engine, 3, FailureBehavior::Erroring);
    let ann = engine.detect_failure().unwrap();
    let report = ReviveMoE::recover(&mut engine, &ann).unwrap();
    assert_eq!(report.role, "collocated");
    assert_eq!(report.moe_recovery, Some(MoeRecoveryKind::RedundantExperts));
    assert!(report.migrated_sequences > 0 || engine.pending() > 0 || true);
    let done = engine.run_to_completion(500).unwrap();
    assert_eq!(early.len() + done.len(), 12);
    engine.shutdown();
}

#[test]
fn baseline_reinit_boots_smaller_world() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let engine = boot(DeploymentConfig::disaggregated_default("artifacts"));
    let ann = engine
        .plugin
        .post_fault(6, FaultLevel::L6, FailureBehavior::Erroring, "test");
    let n_before = engine.cfg.n_moe_ranks;
    let (engine2, bd) = baseline_reinit(engine, &ann).unwrap();
    assert_eq!(engine2.cfg.n_moe_ranks, n_before - 1);
    assert!(bd.total() > std::time::Duration::from_millis(50));
    // the reborn instance actually serves
    let mut engine2 = engine2;
    for r in workload::gen_mixed(4, 41).unwrap() {
        engine2.submit(r).unwrap();
    }
    let done = engine2.run_to_completion(300).unwrap();
    assert_eq!(done.len(), 4);
    engine2.shutdown();
}

#[test]
fn recompile_scope_none_recompiles_nothing() {
    if !ready() {
        eprintln!("SKIP");
        return;
    }
    let mut cfg = DeploymentConfig::disaggregated_default("artifacts");
    cfg.recovery.recompile_scope = RecompileScope::None_;
    let mut engine = boot(cfg);
    let early = serve_some(&mut engine, 8, 43);
    inject(&mut engine, 5, FailureBehavior::Erroring);
    let ann = engine.detect_failure().unwrap();
    let report = ReviveMoE::recover(&mut engine, &ann).unwrap();
    assert_eq!(report.recompiled_graphs, 0);
    // decomposed graphs still serve correctly after the domain change
    let done = engine.run_to_completion(500).unwrap();
    assert_eq!(early.len() + done.len(), 8);
    engine.shutdown();
}
