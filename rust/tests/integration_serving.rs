//! End-to-end serving integration: boot a real deployment over the AOT
//! artifacts, serve, and check the rust pipeline's numerics against the
//! goldens exported by the python oracle (`python/compile/train.py`).
//!
//! These tests need `make artifacts` to have run; they are skipped (with a
//! loud message) otherwise. Booting a deployment compiles ~190 graphs on a
//! single core, so all serving tests share one engine.

use std::path::Path;

use revivemoe::config::DeploymentConfig;
use revivemoe::engine::Engine;
use revivemoe::json::Json;
use revivemoe::workload::{self, EvalSet};

fn artifacts_ready() -> bool {
    Path::new("artifacts/hlo/manifest.json").exists()
        && Path::new("artifacts/golden/golden.json").exists()
}

#[test]
fn serving_pipeline_end_to_end() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let cfg = DeploymentConfig::disaggregated_default("artifacts");
    let (mut engine, bd) = Engine::boot(cfg).unwrap();
    assert!(bd.total().as_millis() > 0);

    // ---------------------------------------------------------------
    // (1) teacher-forced golden parity: rust scoring pipeline must match
    // the python full_forward oracle argmax positions.
    let golden = Json::parse(
        &std::fs::read_to_string("artifacts/golden/golden.json").unwrap(),
    )
    .unwrap();
    let seqs = golden.get("seqs").unwrap().as_arr().unwrap();
    let argmax = golden.get("argmax").unwrap().as_arr().unwrap();
    let mut total = 0usize;
    let mut agree = 0usize;
    for (row, am) in seqs.iter().zip(argmax) {
        let toks: Vec<u16> = row.usize_arr().unwrap().iter().map(|&x| x as u16).collect();
        let expect: Vec<u16> = am.usize_arr().unwrap().iter().map(|&x| x as u16).collect();
        let pred = engine.score_sequence(&toks, 0).unwrap();
        for (p, e) in pred.iter().zip(&expect) {
            total += 1;
            if p == e {
                agree += 1;
            }
        }
    }
    let frac = agree as f64 / total as f64;
    assert!(
        frac > 0.98,
        "rust pipeline argmax agreement with python oracle too low: {frac:.4}"
    );

    // (1b) masked-expert parity: every-4th expert failed
    let masked: Vec<usize> = (0..engine.meta.n_experts).step_by(4).collect();
    engine.expert_map.set_missing(&masked);
    let argmax_m = golden.get("argmax_masked_every4").unwrap().as_arr().unwrap();
    let mut total_m = 0usize;
    let mut agree_m = 0usize;
    for (row, am) in seqs.iter().zip(argmax_m) {
        let toks: Vec<u16> = row.usize_arr().unwrap().iter().map(|&x| x as u16).collect();
        let expect: Vec<u16> = am.usize_arr().unwrap().iter().map(|&x| x as u16).collect();
        let pred = engine.score_sequence(&toks, 0).unwrap();
        for (p, e) in pred.iter().zip(&expect) {
            total_m += 1;
            if p == e {
                agree_m += 1;
            }
        }
    }
    engine.expert_map.clear_missing();
    assert!(
        agree_m as f64 / total_m as f64 > 0.98,
        "masked-gate parity too low"
    );

    // ---------------------------------------------------------------
    // (2) greedy-decode golden parity: serve the golden prompts through
    // the full scheduler/KV/dispatch machinery and compare continuations.
    let decodes = golden.get("decodes").unwrap().as_arr().unwrap();
    let mut ids = Vec::new();
    for d in decodes {
        let prompt = workload::encode(d.get("prompt").unwrap().as_str().unwrap()).unwrap();
        let req = workload::Request {
            task: "golden".into(),
            prompt,
            expected: String::new(),
            max_new_tokens: 8,
        };
        ids.push(engine.submit(req).unwrap());
    }
    let done = engine.run_to_completion(200).unwrap();
    assert_eq!(done.len(), decodes.len(), "all golden prompts must finish");
    let mut matches = 0;
    for c in &done {
        let idx = ids.iter().position(|&i| i == c.seq_id).unwrap();
        let d = &decodes[idx];
        let full: Vec<u16> = d
            .get("output_ids")
            .unwrap()
            .usize_arr()
            .unwrap()
            .iter()
            .map(|&x| x as u16)
            .collect();
        let prompt_len = workload::encode(d.get("prompt").unwrap().as_str().unwrap())
            .unwrap()
            .len();
        let expect_out = &full[prompt_len..];
        if c.output == expect_out {
            matches += 1;
        } else {
            eprintln!(
                "golden mismatch: got {:?} want {:?}",
                workload::decode(&c.output),
                workload::decode(expect_out)
            );
        }
    }
    assert!(
        matches >= decodes.len() - 1,
        "at most one borderline-argmax divergence tolerated: {matches}/{}",
        decodes.len()
    );

    // ---------------------------------------------------------------
    // (3) batched serving: correctness of scheduler bookkeeping under load
    let reqs = workload::gen_mixed(24, 3).unwrap();
    let expected: Vec<String> = reqs.iter().map(|r| r.expected.clone()).collect();
    for r in reqs {
        engine.submit(r).unwrap();
    }
    let done = engine.run_to_completion(500).unwrap();
    assert_eq!(done.len(), 24, "every request completes");
    for c in &done {
        assert!(!c.output.is_empty());
        assert!(c.output.len() <= 16 + 4);
    }
    // the model is small; just require that SOME answers are exactly right
    let right = done
        .iter()
        .filter(|c| {
            let i = (c.seq_id - 5) as usize; // 4 golden seqs came first
            i < expected.len() && workload::decode(&c.output) == expected[i]
        })
        .count();
    assert!(right >= 4, "expected a few exact answers, got {right}/24");

    // (4) eval sets flow through the harness path
    let sets = EvalSet::load_all(Path::new("artifacts/eval")).unwrap();
    let copy = sets["copy"].clone().take(8);
    let acc = revivemoe::evalharness::score_set(&mut engine, &copy).unwrap();
    assert!(acc > 0.2, "copy-task accuracy through rust pipeline: {acc}");

    engine.shutdown();
}
