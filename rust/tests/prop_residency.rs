//! Property-based tests for the tiered expert-memory subsystem
//! (residency hot sets + routing WAL) across randomized placements,
//! capacities, and dispatch streams. Artifact-free: everything here
//! drives [`ExpertResidency`] / [`RoutingWal`] directly.

use std::collections::BTreeSet;

use revivemoe::residency::{ExpertResidency, ResidencyAction, RoutingWal, WAL_WINDOW};
use revivemoe::workload::Rng;

/// Balanced placement: `n_ranks` ranks hosting `per_rank` distinct
/// experts each (global ids unique across ranks, like primaries without
/// redundancy).
fn balanced_slots(n_ranks: usize, per_rank: usize) -> Vec<Vec<usize>> {
    (0..n_ranks).map(|r| (0..per_rank).map(|s| r * per_rank + s).collect()).collect()
}

#[test]
fn hot_set_never_exceeds_capacity_under_random_traffic() {
    for seed in 0..100 {
        let mut rng = Rng::new(91 + seed);
        let n_ranks = rng.below(4) + 1;
        let per_rank = rng.below(7) + 2;
        let capacity = rng.below(per_rank + 2); // 0 (unbounded) .. oversized
        let slots = balanced_slots(n_ranks, per_rank);
        let mut res = ExpertResidency::new(&slots, capacity);
        for _tick in 0..30 {
            for _ in 0..rng.below(40) {
                let rank = rng.below(n_ranks);
                let expert = slots[rank][rng.below(per_rank)];
                res.note_dispatch(rank, expert);
            }
            res.end_tick();
            for (rank, hosted) in slots.iter().enumerate() {
                let hot = res.hot_set(rank);
                let bound = if capacity == 0 { hosted.len() } else { capacity.min(hosted.len()) };
                assert!(
                    hot.len() <= bound,
                    "seed {seed}: rank {rank} hot set {hot:?} over bound {bound}"
                );
                // hot experts are always hosted experts
                let hosted_set: BTreeSet<_> = hosted.iter().copied().collect();
                assert!(hot.iter().all(|e| hosted_set.contains(e)), "seed {seed}: alien expert");
            }
        }
    }
}

#[test]
fn actions_are_a_pure_function_of_the_dispatch_stream() {
    for seed in 0..60 {
        let mut rng = Rng::new(417 + seed);
        let n_ranks = rng.below(3) + 1;
        let per_rank = rng.below(6) + 2;
        let capacity = rng.below(per_rank) + 1;
        let slots = balanced_slots(n_ranks, per_rank);
        // one pre-drawn stream of (tick boundary | dispatch) events
        let mut stream: Vec<Option<(usize, usize)>> = Vec::new();
        for _tick in 0..20 {
            for _ in 0..rng.below(25) {
                let rank = rng.below(n_ranks);
                stream.push(Some((rank, slots[rank][rng.below(per_rank)])));
            }
            stream.push(None);
        }
        let replay = |stream: &[Option<(usize, usize)>]| {
            let mut res = ExpertResidency::new(&slots, capacity);
            let mut actions = Vec::new();
            let mut hots = Vec::new();
            for ev in stream {
                match ev {
                    Some((rank, expert)) => {
                        res.note_dispatch(*rank, *expert);
                    }
                    None => {
                        actions.extend(res.end_tick());
                        hots.push((0..n_ranks).map(|r| res.hot_set(r)).collect::<Vec<_>>());
                    }
                }
            }
            (actions, hots)
        };
        let (a1, h1) = replay(&stream);
        let (a2, h2) = replay(&stream);
        assert_eq!(a1, a2, "seed {seed}: action sequences diverged");
        assert_eq!(h1, h2, "seed {seed}: hot-set histories diverged");
        // every action's rank/expert is well-formed
        for act in &a1 {
            let (rank, expert) = match act {
                ResidencyAction::Promote { rank, expert } => (*rank, *expert),
                ResidencyAction::Evict { rank, expert } => (*rank, *expert),
            };
            assert!(rank < n_ranks && slots[rank].contains(&expert), "seed {seed}: {act:?}");
        }
    }
}

#[test]
fn promotions_and_evictions_mirror_the_hot_set_delta() {
    // The action list IS the hot-set diff: applying Promote/Evict to the
    // previous hot set must reproduce the next one exactly.
    for seed in 0..60 {
        let mut rng = Rng::new(3301 + seed);
        let per_rank = rng.below(6) + 3;
        let capacity = rng.below(per_rank - 1) + 1;
        let slots = balanced_slots(2, per_rank);
        let mut res = ExpertResidency::new(&slots, capacity);
        let mut model: Vec<BTreeSet<usize>> =
            (0..2).map(|r| res.hot_set(r).into_iter().collect()).collect();
        for _tick in 0..25 {
            for _ in 0..rng.below(30) {
                let rank = rng.below(2);
                res.note_dispatch(rank, slots[rank][rng.below(per_rank)]);
            }
            for act in res.end_tick() {
                match act {
                    ResidencyAction::Promote { rank, expert } => {
                        assert!(model[rank].insert(expert), "seed {seed}: double promote {act:?}")
                    }
                    ResidencyAction::Evict { rank, expert } => {
                        assert!(model[rank].remove(&expert), "seed {seed}: evicting cold {act:?}")
                    }
                }
            }
            for r in 0..2 {
                let got: BTreeSet<usize> = res.hot_set(r).into_iter().collect();
                assert_eq!(got, model[r], "seed {seed}: hot set diverged from the action diff");
            }
        }
    }
}

#[test]
fn wal_window_matches_a_naive_model_across_random_streams() {
    for seed in 0..60 {
        let mut rng = Rng::new(5511 + seed);
        let n_seqs = rng.below(4) + 1;
        let mut wal = RoutingWal::new();
        // naive model: unbounded per-seq vec, truncated to the window
        let mut naive: Vec<Vec<(u16, Vec<(usize, usize)>)>> = vec![Vec::new(); n_seqs];
        for step in 0..80u16 {
            for seq in 0..n_seqs {
                if rng.below(4) == 0 {
                    continue; // this seq skipped the step
                }
                let mut routes = Vec::new();
                for layer in 2..2 + rng.below(3) + 1 {
                    let experts: Vec<usize> = (0..2).map(|_| rng.below(16)).collect();
                    wal.stage(seq as u64, layer, &experts);
                    routes.extend(experts.iter().map(|&e| (layer, e)));
                }
                wal.commit(seq as u64, step);
                naive[seq].push((step, routes));
                if naive[seq].len() > WAL_WINDOW {
                    naive[seq].remove(0);
                }
            }
        }
        for seq in 0..n_seqs {
            let got: Vec<_> =
                wal.records(seq as u64).map(|r| (r.token, r.routes.clone())).collect();
            assert_eq!(got, naive[seq], "seed {seed}: seq {seq} window diverged");
        }
        let total: usize = naive.iter().map(|w| w.len()).sum();
        assert_eq!(wal.total_tokens(), total, "seed {seed}");
    }
}

#[test]
fn abort_never_leaks_partial_step_entries() {
    for seed in 0..60 {
        let mut rng = Rng::new(7741 + seed);
        let mut wal = RoutingWal::new();
        let mut committed: Vec<Vec<u16>> = vec![Vec::new(); 3];
        for step in 0..60u16 {
            for seq in 0..3u64 {
                wal.stage(seq, 2, &[rng.below(8), rng.below(8)]);
            }
            if rng.below(3) == 0 {
                // the step aborts: staged routing must vanish, committed
                // windows must be untouched
                wal.abort();
            } else {
                for seq in 0..3u64 {
                    wal.commit(seq, step);
                    committed[seq as usize].push(step);
                    if committed[seq as usize].len() > WAL_WINDOW {
                        committed[seq as usize].remove(0);
                    }
                }
            }
            for seq in 0..3u64 {
                let tokens: Vec<u16> = wal.records(seq).map(|r| r.token).collect();
                assert_eq!(tokens, committed[seq as usize], "seed {seed}: partial step leaked");
                // every surviving record carries real routes: an aborted
                // step can never have committed an empty-staged record
                assert!(wal.records(seq).all(|r| !r.routes.is_empty()), "seed {seed}");
            }
        }
        for seq in 0..3u64 {
            wal.drop_seq(seq);
        }
        assert!(wal.is_empty(), "seed {seed}: drop_seq left state behind");
    }
}
