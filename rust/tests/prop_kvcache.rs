//! Property-based tests for the block manager's undo log (§3.3).
//!
//! The offline build carries no proptest crate, so this uses the in-tree
//! deterministic xorshift generator to drive randomized operation
//! sequences — same idea: arbitrary interleavings of block ops within a
//! step must be perfectly reversed by `undo_step`.

use revivemoe::kvcache::BlockManager;
use revivemoe::workload::Rng;

/// Apply a random (but valid) block op; returns false if nothing applied.
fn random_op(m: &mut BlockManager, rng: &mut Rng, live_seqs: &mut Vec<u64>) -> bool {
    let choice = rng.below(100);
    match choice {
        // append to an existing or new sequence (most common op)
        0..=59 => {
            let seq = if live_seqs.is_empty() || rng.below(4) == 0 {
                let s = rng.below(1000) as u64 + 1;
                if !live_seqs.contains(&s) {
                    live_seqs.push(s);
                }
                s
            } else {
                live_seqs[rng.below(live_seqs.len())]
            };
            m.append_token(seq).is_ok()
        }
        // ref-bump a random block of a random sequence
        60..=69 => {
            if live_seqs.is_empty() {
                return false;
            }
            let seq = live_seqs[rng.below(live_seqs.len())];
            let Some(t) = m.table(seq) else { return false };
            if t.blocks.is_empty() {
                return false;
            }
            let b = t.blocks[rng.below(t.blocks.len())];
            m.ref_inc(b).is_ok()
        }
        // trim the last block
        70..=79 => {
            if live_seqs.is_empty() {
                return false;
            }
            let seq = live_seqs[rng.below(live_seqs.len())];
            if m.table(seq).map(|t| t.blocks.is_empty()).unwrap_or(true) {
                return false;
            }
            m.free_last(seq).is_ok()
        }
        // finish a sequence entirely
        _ => {
            if live_seqs.is_empty() {
                return false;
            }
            let i = rng.below(live_seqs.len());
            let seq = live_seqs[i];
            if m.table(seq).is_none() {
                return false;
            }
            live_seqs.swap_remove(i);
            m.drop_sequence(seq).is_ok()
        }
    }
}

#[test]
fn undo_restores_any_random_step() {
    for trial in 0..200 {
        let mut rng = Rng::new(0xC0FFEE + trial);
        let mut m = BlockManager::new(64, 4);
        let mut live = Vec::new();
        // build up arbitrary pre-state (committed steps)
        for _ in 0..rng.below(120) {
            random_op(&mut m, &mut rng, &mut live);
        }
        m.begin_step();
        let snap = m.snapshot();
        let live_snap = live.clone();
        // a failed step with up to 40 random ops
        for _ in 0..rng.below(40) + 1 {
            random_op(&mut m, &mut rng, &mut live);
        }
        m.undo_step().expect("undo must succeed");
        assert_eq!(m.snapshot(), snap, "trial {trial}: state must match step start");
        m.audit().expect("audit after undo");
        live = live_snap;
        // the manager must still be fully usable after an undo
        for _ in 0..20 {
            random_op(&mut m, &mut rng, &mut live);
        }
        m.audit().expect("audit after continued use");
    }
}

#[test]
fn undo_is_idempotent_on_empty_log() {
    let mut m = BlockManager::new(8, 4);
    for _ in 0..5 {
        m.append_token(1).unwrap();
    }
    m.begin_step();
    let snap = m.snapshot();
    assert_eq!(m.undo_step().unwrap(), 0);
    assert_eq!(m.undo_step().unwrap(), 0);
    assert_eq!(m.snapshot(), snap);
}

#[test]
fn interleaved_sequences_roundtrip() {
    // two sequences interleaving appends across block boundaries
    for seed in 0..50 {
        let mut rng = Rng::new(7000 + seed);
        let mut m = BlockManager::new(32, 2); // tiny blocks force allocs
        for _ in 0..10 {
            m.append_token(1).unwrap();
            m.append_token(2).unwrap();
        }
        m.begin_step();
        let snap = m.snapshot();
        for _ in 0..rng.below(16) + 1 {
            let s = 1 + rng.below(2) as u64;
            m.append_token(s).unwrap();
        }
        if rng.below(2) == 0 {
            m.drop_sequence(2).unwrap();
        }
        m.undo_step().unwrap();
        assert_eq!(m.snapshot(), snap);
    }
}

#[test]
fn oom_mid_step_is_recoverable() {
    let mut m = BlockManager::new(4, 1); // 4 single-token blocks
    m.append_token(1).unwrap();
    m.append_token(1).unwrap();
    m.begin_step();
    let snap = m.snapshot();
    m.append_token(2).unwrap();
    m.append_token(2).unwrap();
    assert!(m.append_token(3).is_err(), "pool exhausted");
    // failure: roll the partial step back
    m.undo_step().unwrap();
    assert_eq!(m.snapshot(), snap);
    assert_eq!(m.n_free(), 2);
}
