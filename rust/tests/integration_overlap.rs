//! Overlapped-data-plane integration: the async submit/await engine must
//! produce bit-identical token streams to the serialized baseline, and a
//! device that goes `Hung` mid-step must surface as a timeout error from
//! the decode step — never a deadlock.
//!
//! Needs `make artifacts` (skipped loudly otherwise), like the other
//! integration suites.

use std::path::Path;
use std::time::{Duration, Instant};

use revivemoe::cluster::FailureBehavior;
use revivemoe::config::DeploymentConfig;
use revivemoe::engine::Engine;
use revivemoe::scheduler::Token;
use revivemoe::workload;

fn artifacts_ready() -> bool {
    Path::new("artifacts/hlo/manifest.json").exists()
}

/// Serve `n` fixed requests to completion and return the decoded streams
/// in submission order.
fn serve(engine: &mut Engine, n: usize, serial: bool) -> Vec<Vec<Token>> {
    engine.cfg.serial_data_plane = serial;
    let reqs = workload::gen_mixed(n, 11).expect("workload");
    let mut ids = Vec::with_capacity(n);
    for r in reqs {
        ids.push(engine.submit(r).expect("submit"));
    }
    let done = engine.run_to_completion(500).expect("serve");
    assert_eq!(done.len(), n, "every request must complete");
    ids.iter()
        .map(|id| done.iter().find(|c| c.seq_id == *id).unwrap().output.clone())
        .collect()
}

#[test]
fn overlapped_decode_matches_serial_token_streams() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    for cfg in [
        DeploymentConfig::disaggregated_default("artifacts"),
        DeploymentConfig::collocated_default("artifacts"),
    ] {
        let mode = cfg.mode;
        let (mut engine, _bd) = Engine::boot(cfg).unwrap();
        // same engine, same prompts: greedy decode is deterministic, so the
        // serialized and overlapped data planes must agree token-for-token
        let serial = serve(&mut engine, 12, true);
        let overlap = serve(&mut engine, 12, false);
        assert_eq!(
            serial, overlap,
            "overlapped decode diverged from the serial baseline ({mode:?})"
        );
        engine.shutdown();
    }
}

#[test]
fn hung_device_mid_step_times_out_instead_of_deadlocking() {
    if !artifacts_ready() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let (mut engine, _bd) = Engine::boot(DeploymentConfig::collocated_default("artifacts")).unwrap();
    for r in workload::gen_mixed(8, 3).expect("workload") {
        engine.submit(r).expect("submit");
    }
    // prefill + one healthy decode step so every rank is mid-generation
    engine.step().expect("healthy step");

    // hang one attention rank; shorten every per-command deadline so the
    // test is fast (the default is 5s — correctness, not the constant,
    // is what we assert)
    let victim = engine.attn_order[0];
    for ex in engine.executors.values_mut() {
        ex.handle.cmd_timeout = Duration::from_millis(300);
    }
    engine.executors[&victim].handle.set_failed(FailureBehavior::Hung);

    let t0 = Instant::now();
    let err = engine.step().expect_err("step over a hung device must fail");
    let elapsed = t0.elapsed();
    assert!(
        err.to_string().contains("timed out"),
        "expected a timeout error, got: {err}"
    );
    assert!(
        elapsed < Duration::from_secs(10),
        "timeout must be deadline-bounded, took {elapsed:?}"
    );
    // the failure is also visible to the detection machinery
    let ann = engine.detect_failure().expect("heartbeat sweep must flag the hung device");
    assert_eq!(ann.device, victim);
    engine.shutdown();
}
