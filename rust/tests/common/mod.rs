//! Shared helpers for the serve-loop integration suites: the artifact
//! gate, the boot-serve-shutdown driver every suite used to hand-roll,
//! and the replay-equality assertion (token streams + full event log +
//! tick count + every `RecoveryRecord` field-by-field, in order).
//!
//! Integration binaries pull this in with `mod common;` — each only uses
//! a subset, hence the `dead_code` allowance.
#![allow(dead_code)]

use std::path::Path;

use revivemoe::config::DeploymentConfig;
use revivemoe::engine::Engine;
use revivemoe::scenario::Scenario;
use revivemoe::serve::{run_scenario, RecoveryStrategy, ServeReport};

/// True once `make artifacts` has produced the HLO manifest the engine
/// boots from; suites skip loudly when it is absent.
pub fn ready() -> bool {
    Path::new("artifacts/hlo/manifest.json").exists()
}

/// The deployment every serve suite boots unless it needs custom knobs.
pub fn default_cfg() -> DeploymentConfig {
    DeploymentConfig::disaggregated_default("artifacts")
}

/// Boot `cfg`, serve `scenario` under `strategy`, shut down, return the
/// report.
pub fn run_with(
    cfg: DeploymentConfig,
    scenario: &Scenario,
    strategy: RecoveryStrategy,
) -> ServeReport {
    let (engine, _bd) = Engine::boot(cfg).expect("boot");
    let (engine, report) = run_scenario(engine, scenario, strategy).expect("serve");
    engine.shutdown();
    report
}

/// [`run_with`] under the default ReviveMoE strategy.
pub fn run(cfg: DeploymentConfig, scenario: &Scenario) -> ServeReport {
    run_with(cfg, scenario, RecoveryStrategy::ReviveMoE)
}

/// Assert two runs of the same scenario replayed identically over the
/// whole determinism surface: token streams per arrival, the complete
/// tick-stamped event log, the tick count, and the recovery records in
/// order with every deterministic field equal (`stall_ms` is wall clock
/// and deliberately excluded).
pub fn assert_replay_identical(a: &ServeReport, b: &ServeReport) {
    assert_eq!(a.token_streams(), b.token_streams(), "token streams must replay");
    assert_eq!(a.event_log, b.event_log, "event ordering must replay");
    assert_eq!(a.ticks, b.ticks, "tick counts must replay");
    assert_eq!(
        a.recoveries.len(),
        b.recoveries.len(),
        "recovery counts must replay: {:?} vs {:?}",
        a.recoveries,
        b.recoveries
    );
    for (i, (ra, rb)) in a.recoveries.iter().zip(&b.recoveries).enumerate() {
        assert_eq!(ra.tick, rb.tick, "recovery {i}: tick diverged");
        assert_eq!(ra.device, rb.device, "recovery {i}: device diverged");
        assert_eq!(ra.kind, rb.kind, "recovery {i}: kind diverged");
        assert_eq!(
            ra.moved_sequences, rb.moved_sequences,
            "recovery {i}: moved_sequences diverged"
        );
        assert_eq!(ra.degraded, rb.degraded, "recovery {i}: degraded flag diverged");
    }
}
