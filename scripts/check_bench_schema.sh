#!/usr/bin/env bash
# Validate the repo-root BENCH_*.json baselines against the shared
# placeholder/real-run convention, so the checked-in files cannot rot
# silently (wired into ci.yml).
#
# The convention (shared by every bench that writes a baseline):
#   - every file is valid JSON with a "bench" name and a "rows" array;
#     decode_throughput predates "rows" and uses "shapes" instead;
#   - a *placeholder* (no toolchain ran the bench) declares
#     "status": "not-run", explains itself in "note", names its
#     "regenerate" wrapper script (which must exist and be executable),
#     and carries only-null metric values in its rows;
#   - a *real* run drops "status"/"note" and has no null metrics — a
#     mixed file (claiming not-run but carrying numbers, or claiming run
#     while still full of nulls) fails the check.
#
# Usage: scripts/check_bench_schema.sh   (from anywhere; cds to repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

python3 - <<'EOF'
import glob
import json
import os
import sys

# every repo-root baseline is validated — a glob, not a hardcoded list,
# so a newly added BENCH_*.json cannot silently escape the check
FILES = sorted(glob.glob("BENCH_*.json"))

failures = []
if not FILES:
    failures.append("no BENCH_*.json baselines found at the repo root")

# every bench target checks in a baseline; keep this count in lockstep
# with the [[bench]] JSON-writing targets so a new bench cannot land
# without one (or an old baseline vanish unnoticed)
EXPECTED = 8
if FILES and len(FILES) != EXPECTED:
    failures.append(
        f"expected {EXPECTED} BENCH_*.json baselines, found {len(FILES)}: "
        + ", ".join(FILES))


def rows_of(doc):
    # decode_throughput predates the "rows" convention and uses "shapes"
    for key in ("rows", "shapes"):
        if key in doc:
            if not isinstance(doc[key], list) or not doc[key]:
                return key, None
            return key, doc[key]
    return None, None


def null_metrics(rows):
    """(nulls, non_nulls) over every non-identity field of every row."""
    identity = {"scenario", "strategy", "mode", "label", "ranks", "scope",
                "degraded_serving", "attn_ranks", "batch_per_rank", "ctx"}
    nulls = non_nulls = 0
    for row in rows:
        if not isinstance(row, dict):
            return None
        for k, v in row.items():
            if k in identity or isinstance(v, (str, bool)):
                continue
            if v is None:
                nulls += 1
            else:
                non_nulls += 1
    return nulls, non_nulls


for path in FILES:
    if not os.path.exists(path):
        failures.append(f"{path}: missing")
        continue
    try:
        with open(path) as f:
            doc = json.load(f)
    except json.JSONDecodeError as e:
        failures.append(f"{path}: invalid JSON ({e})")
        continue
    if "bench" not in doc:
        failures.append(f"{path}: no \"bench\" name")
        continue
    key, rows = rows_of(doc)
    if rows is None:
        failures.append(f"{path}: no non-empty \"rows\"/\"shapes\" array")
        continue
    counted = null_metrics(rows)
    if counted is None:
        failures.append(f"{path}: {key} entries must be objects")
        continue
    nulls, non_nulls = counted
    placeholder = doc.get("status") == "not-run"
    if placeholder:
        if "note" not in doc:
            failures.append(f"{path}: placeholder without a \"note\"")
        regen = doc.get("regenerate")
        if not regen:
            failures.append(f"{path}: placeholder without a \"regenerate\" wrapper")
        elif not os.access(regen, os.X_OK):
            failures.append(f"{path}: regenerate wrapper {regen!r} missing or not executable")
        if non_nulls:
            failures.append(
                f"{path}: claims \"status\": \"not-run\" but carries {non_nulls} "
                "non-null metric value(s) — stale placeholder marker?")
    else:
        if nulls:
            failures.append(
                f"{path}: claims a real run but still has {nulls} null metric "
                "value(s) — regenerate or mark \"status\": \"not-run\"")
    state = "placeholder" if placeholder else "real run"
    print(f"  {path}: {state}, {len(rows)} {key}")

if failures:
    print("\nBENCH schema check FAILED:", file=sys.stderr)
    for f in failures:
        print(f"  - {f}", file=sys.stderr)
    sys.exit(1)
print("BENCH schema check OK")
EOF
