#!/usr/bin/env bash
# Regenerate the predictive-health detection baseline.
#
# Runs the canned degradation scenarios (slow-node | flaky-node |
# degrading-node) under the serve loop in reactive (HealthPolicy off)
# and predictive (detection on) modes and refreshes
# BENCH_health_detection.json at the repo root (the bench also writes
# rust/bench_results/health_detection.json).
#
# Usage: scripts/bench_health.sh [QUICK=1 for a smoke run]
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/hlo/manifest.json ]; then
    echo "ERROR: AOT artifacts missing — run \`make artifacts\` first" >&2
    exit 1
fi

# a placeholder baseline is checked in, so existence proves nothing:
# require the file's mtime to advance across the bench run
before=$(stat -c %Y BENCH_health_detection.json 2>/dev/null || echo 0)

(cd rust && cargo bench --bench health_detection)

after=$(stat -c %Y BENCH_health_detection.json 2>/dev/null || echo 0)
if [ "$after" -le "$before" ]; then
    # the bench's repo-root write failed (it warns on stderr); fall back
    # to the bench_results artifact it writes from inside rust/
    cp rust/bench_results/health_detection.json BENCH_health_detection.json
    echo "BENCH_health_detection.json copied from rust/bench_results/"
fi
echo "BENCH_health_detection.json refreshed:"
head -c 400 BENCH_health_detection.json; echo
