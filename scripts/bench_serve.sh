#!/usr/bin/env bash
# Regenerate the online fault-scenario serving baseline.
#
# Runs every canned scenario (steady, single-fault, cascade, fault-revive)
# under both recovery strategies (ReviveMoE in place vs cached reinit) and
# refreshes BENCH_serve_scenarios.json at the repo root (the bench also
# writes rust/bench_results/serve_scenarios.json).
#
# Usage: scripts/bench_serve.sh [QUICK=1 for a smoke run]
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/hlo/manifest.json ]; then
    echo "ERROR: AOT artifacts missing — run \`make artifacts\` first" >&2
    exit 1
fi

# a placeholder baseline is checked in, so existence proves nothing:
# require the file's mtime to advance across the bench run
before=$(stat -c %Y BENCH_serve_scenarios.json 2>/dev/null || echo 0)

(cd rust && cargo bench --bench serve_scenarios)

after=$(stat -c %Y BENCH_serve_scenarios.json 2>/dev/null || echo 0)
if [ "$after" -le "$before" ]; then
    # the bench's repo-root write failed (it warns on stderr); fall back
    # to the bench_results artifact it writes from inside rust/
    cp rust/bench_results/serve_scenarios.json BENCH_serve_scenarios.json
    echo "BENCH_serve_scenarios.json copied from rust/bench_results/"
fi
echo "BENCH_serve_scenarios.json refreshed:"
head -c 400 BENCH_serve_scenarios.json; echo
