#!/usr/bin/env bash
# Regenerate the prefill-chunking / continuous-batching baseline.
#
# Sweeps the rate-surge and fault-surge scenarios under monolithic vs
# chunked vs chunked+budgeted serving (TTFT split, TPOT, decode step
# p50, chunk/preemption counters), plus the KV-pressure preemption
# micro-bench (mirror spill/restore vs lossy requeue), and refreshes
# BENCH_prefill_chunking.json at the repo root (the bench also writes
# rust/bench_results/prefill_chunking.json).
#
# Usage: scripts/bench_chunking.sh [QUICK=1 for a smoke run]
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/hlo/manifest.json ]; then
    echo "ERROR: AOT artifacts missing — run \`make artifacts\` first" >&2
    exit 1
fi

# a placeholder baseline is checked in, so existence proves nothing:
# require the file's mtime to advance across the bench run
before=$(stat -c %Y BENCH_prefill_chunking.json 2>/dev/null || echo 0)

(cd rust && cargo bench --bench prefill_chunking)

after=$(stat -c %Y BENCH_prefill_chunking.json 2>/dev/null || echo 0)
if [ "$after" -le "$before" ]; then
    # the bench's repo-root write failed (it warns on stderr); fall back
    # to the bench_results artifact it writes from inside rust/
    cp rust/bench_results/prefill_chunking.json BENCH_prefill_chunking.json
    echo "BENCH_prefill_chunking.json copied from rust/bench_results/"
fi
echo "BENCH_prefill_chunking.json refreshed:"
head -c 400 BENCH_prefill_chunking.json; echo
