#!/usr/bin/env bash
# Regenerate the recovery-latency perf baseline.
#
# Runs the serial-vs-overlapped recovery bench (fail + recover + revive)
# over the 2/4/8-rank disaggregated shapes per RecompileScope and
# refreshes BENCH_recovery_latency.json at the repo root (the bench also
# writes rust/bench_results/recovery_latency.json).
#
# Usage: scripts/bench_recovery.sh [QUICK=1 for a smoke run]
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/hlo/manifest.json ]; then
    echo "ERROR: AOT artifacts missing — run \`make artifacts\` first" >&2
    exit 1
fi

# a placeholder baseline is checked in, so existence proves nothing:
# require the file's mtime to advance across the bench run
before=$(stat -c %Y BENCH_recovery_latency.json 2>/dev/null || echo 0)

(cd rust && cargo bench --bench recovery_latency)

after=$(stat -c %Y BENCH_recovery_latency.json 2>/dev/null || echo 0)
if [ "$after" -le "$before" ]; then
    # the bench's repo-root write failed (it warns on stderr); fall back
    # to the bench_results artifact it writes from inside rust/
    cp rust/bench_results/recovery_latency.json BENCH_recovery_latency.json
    echo "BENCH_recovery_latency.json copied from rust/bench_results/"
fi
echo "BENCH_recovery_latency.json refreshed:"
head -c 400 BENCH_recovery_latency.json; echo
