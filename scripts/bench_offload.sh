#!/usr/bin/env bash
# Regenerate the expert-offload baseline.
#
# Part A compares the §3.4 role-switch recovery with the disk
# weight-reload vs the wal-replay mode (host-tier expert upload + routing
# WAL replay over live-migrated KV: zero disk reads, zero recomputed
# tokens). Part B sweeps the resident hot fraction (1.0/0.5/0.25 of each
# rank's expert slots) under steady decode and reports per-step overhead,
# cold hits, and promotion traffic. Refreshes BENCH_expert_offload.json
# at the repo root (the bench also writes
# rust/bench_results/expert_offload.json).
#
# Usage: scripts/bench_offload.sh [QUICK=1 for a smoke run]
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/hlo/manifest.json ]; then
    echo "ERROR: AOT artifacts missing — run \`make artifacts\` first" >&2
    exit 1
fi

# a placeholder baseline is checked in, so existence proves nothing:
# require the file's mtime to advance across the bench run
before=$(stat -c %Y BENCH_expert_offload.json 2>/dev/null || echo 0)

(cd rust && cargo bench --bench expert_offload)

after=$(stat -c %Y BENCH_expert_offload.json 2>/dev/null || echo 0)
if [ "$after" -le "$before" ]; then
    # the bench's repo-root write failed (it warns on stderr); fall back
    # to the bench_results artifact it writes from inside rust/
    cp rust/bench_results/expert_offload.json BENCH_expert_offload.json
    echo "BENCH_expert_offload.json copied from rust/bench_results/"
fi
echo "BENCH_expert_offload.json refreshed:"
head -c 400 BENCH_expert_offload.json; echo
