#!/usr/bin/env bash
# Regenerate the decode-throughput perf baseline.
#
# Runs the serial-vs-overlapped decode bench over the 1/2/4/8-rank shapes
# in both deploy modes and refreshes BENCH_decode_throughput.json at the
# repo root (the bench also writes rust/bench_results/decode_throughput.json).
#
# Usage: scripts/bench_decode.sh [QUICK=1 for a smoke run]
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/hlo/manifest.json ]; then
    echo "ERROR: AOT artifacts missing — run \`make artifacts\` first" >&2
    exit 1
fi

# a placeholder baseline is checked in, so existence proves nothing:
# require the file's mtime to advance across the bench run
before=$(stat -c %Y BENCH_decode_throughput.json 2>/dev/null || echo 0)

(cd rust && cargo bench --bench decode_throughput)

after=$(stat -c %Y BENCH_decode_throughput.json 2>/dev/null || echo 0)
if [ "$after" -le "$before" ]; then
    # the bench's repo-root write failed (it warns on stderr); fall back
    # to the bench_results artifact it writes from inside rust/
    cp rust/bench_results/decode_throughput.json BENCH_decode_throughput.json
    echo "BENCH_decode_throughput.json copied from rust/bench_results/"
fi
echo "BENCH_decode_throughput.json refreshed:"
head -c 400 BENCH_decode_throughput.json; echo
