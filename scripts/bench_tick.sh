#!/usr/bin/env bash
# Regenerate the decode tick-overhead baseline.
#
# Runs the coordinator-side tick cost bench (per-command baseline vs
# coalesced ExecuteBatch submission across rank count x per-rank batch
# size: step wall time, thread-local heap allocations per tick, and
# Execute-class submissions per tick) and refreshes
# BENCH_decode_tick_overhead.json at the repo root (the bench also
# writes rust/bench_results/decode_tick_overhead.json).
#
# Usage: scripts/bench_tick.sh [QUICK=1 for a smoke run]
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/hlo/manifest.json ]; then
    echo "ERROR: AOT artifacts missing — run \`make artifacts\` first" >&2
    exit 1
fi

# a placeholder baseline is checked in, so existence proves nothing:
# require the file's mtime to advance across the bench run
before=$(stat -c %Y BENCH_decode_tick_overhead.json 2>/dev/null || echo 0)

(cd rust && cargo bench --bench decode_tick_overhead)

after=$(stat -c %Y BENCH_decode_tick_overhead.json 2>/dev/null || echo 0)
if [ "$after" -le "$before" ]; then
    # the bench's repo-root write failed (it warns on stderr); fall back
    # to the bench_results artifact it writes from inside rust/
    cp rust/bench_results/decode_tick_overhead.json BENCH_decode_tick_overhead.json
    echo "BENCH_decode_tick_overhead.json copied from rust/bench_results/"
fi
echo "BENCH_decode_tick_overhead.json refreshed:"
head -c 400 BENCH_decode_tick_overhead.json; echo
