#!/usr/bin/env bash
# Regenerate the KV-migration baseline.
#
# Sweeps context length x attention-rank count x migration mode over two
# fault families (role-switch with a healthy victim: reprefill vs
# live-migrate; attention-rank death: reprefill vs host-mirror) and
# refreshes BENCH_kv_migration.json at the repo root (the bench also
# writes rust/bench_results/kv_migration.json).
#
# Usage: scripts/bench_kv.sh [QUICK=1 for a smoke run]
set -euo pipefail
cd "$(dirname "$0")/.."

if [ ! -f rust/artifacts/hlo/manifest.json ]; then
    echo "ERROR: AOT artifacts missing — run \`make artifacts\` first" >&2
    exit 1
fi

# a placeholder baseline is checked in, so existence proves nothing:
# require the file's mtime to advance across the bench run
before=$(stat -c %Y BENCH_kv_migration.json 2>/dev/null || echo 0)

(cd rust && cargo bench --bench kv_migration)

after=$(stat -c %Y BENCH_kv_migration.json 2>/dev/null || echo 0)
if [ "$after" -le "$before" ]; then
    # the bench's repo-root write failed (it warns on stderr); fall back
    # to the bench_results artifact it writes from inside rust/
    cp rust/bench_results/kv_migration.json BENCH_kv_migration.json
    echo "BENCH_kv_migration.json copied from rust/bench_results/"
fi
echo "BENCH_kv_migration.json refreshed:"
head -c 400 BENCH_kv_migration.json; echo
