//! Quickstart: the lowest-level path through the stack.
//!
//! Spawns ONE simulated NPU, loads the full weight set, compiles the fused
//! "graph mode" decode executable (`full_decode_b1` — the whole model
//! forward as a single kernel launch, §2.4), and greedy-decodes a few
//! prompts token by token. No engine, no scheduler: just the runtime.
//!
//! Paper correspondence: §2.4's "graph mode" claim — when one rank hosts
//! the whole model, the entire decode step runs as a single fused graph
//! launch (`full_decode_b1`), the configuration whose recompile cost
//! motivates the §3.6 cached-compile machinery.
//!
//! Run: `cargo run --release --example quickstart`

use revivemoe::artifacts::ArtifactStore;
use revivemoe::config::ModelMeta;
use revivemoe::runtime::{Arg, SimDevice};
use revivemoe::tensor::Tensor;
use revivemoe::weights::WeightStore;
use revivemoe::workload;
use revivemoe::Result;

fn main() -> Result<()> {
    let art = std::path::Path::new("artifacts");
    let meta = ModelMeta::load(art)?;
    let store = WeightStore::open(&art.join("weights.json"), &art.join("weights.bin"))?;
    let arts = ArtifactStore::open(&art.join("hlo"))?;

    // one device; everything fits on it ("EP1" deployment)
    let dev = SimDevice::spawn(0);
    let t0 = std::time::Instant::now();
    let weights = store.load_all()?;
    let n_bytes = dev.handle.load_weights(weights)?;
    println!("loaded {} weight tensors ({} KiB) in {:?}",
             store.names().count(), n_bytes / 1024, t0.elapsed());

    let stat = dev.handle.compile("full_decode_b1", arts.path("full_decode_b1")?)?;
    println!("cached-compiled the fused graph-mode executable in {:.2}s \
              (read {:.3}s, {} B of HLO)",
             stat.compile_s, stat.read_s, stat.hlo_bytes);

    let (h, dh, l, s) = (meta.n_heads, meta.d_head, meta.n_layers, meta.max_seq);
    let weight_names: Vec<String> = store.names().map(|s| s.to_string()).collect();

    for prompt in ["c:hello>", "a:12+30>", "o:dcba>", "m:2957>"] {
        let mut toks = workload::encode(prompt)?;
        // host-held KV cache for the fused graph (single rank: no paging)
        let mut kc = Tensor::zeros(vec![l, 1, s, h, dh]);
        let mut vc = Tensor::zeros(vec![l, 1, s, h, dh]);
        let start = toks.len();
        let mut pos = 0;
        while pos < toks.len() && toks.len() <= start + 10 {
            let mut args = vec![
                Arg::Value(Tensor::i32(vec![1], vec![toks[pos] as i32])),
                Arg::Value(Tensor::i32(vec![1], vec![pos as i32])),
                Arg::Value(kc.clone()),
                Arg::Value(vc.clone()),
                Arg::Value(Tensor::i32(vec![1], vec![pos as i32])),
                Arg::Value(Tensor::zeros(vec![meta.n_experts])), // no failed experts
            ];
            args.extend(weight_names.iter().map(|n| Arg::Weight(n.clone())));
            let out = dev.handle.execute("full_decode_b1", args)?;
            let (logits, nk, nv) = (&out[0], &out[1], &out[2]);
            // write this token's K/V row at `pos` for every layer
            let row = h * dh;
            {
                let src = nk.as_f32()?.to_vec();
                let srcv = nv.as_f32()?.to_vec();
                let ko = kc.as_f32_mut()?;
                for li in 0..l {
                    let off = (li * s + pos) * row;
                    ko[off..off + row].copy_from_slice(&src[li * row..(li + 1) * row]);
                }
                let vo = vc.as_f32_mut()?;
                for li in 0..l {
                    let off = (li * s + pos) * row;
                    vo[off..off + row].copy_from_slice(&srcv[li * row..(li + 1) * row]);
                }
            }
            // only start emitting once the prompt is consumed
            if pos + 1 >= toks.len() {
                let next = logits.argmax_rows()?[0] as u16;
                if next == workload::eos_token() {
                    toks.push(next);
                    break;
                }
                toks.push(next);
            }
            pos += 1;
        }
        println!("{prompt:<12} -> {:?}", workload::decode(&toks[start..]));
    }

    dev.handle.shutdown();
    Ok(())
}
