//! End-to-end serving driver (the repository's headline example).
//!
//! Boots the paper's main deployment shape — MA-disaggregated, 8 simulated
//! NPUs: 4 attention (DP) ranks + 4 MoE (EP4) ranks over the trained tiny
//! MoE — then serves a batched multi-task workload through the full
//! engine/scheduler/paged-KV/XCCL-sim pipeline and reports throughput,
//! latency percentiles, TTFT, answer accuracy per task family, and the
//! dispatch/combine byte traffic.
//!
//! Paper correspondence: Figure 2(b), the MA-disaggregated deployment —
//! attention DP ranks feeding MoE EP ranks through XCCL A2E/E2A — serving
//! the §4 testbed workload with no faults injected (the healthy control
//! every recovery experiment compares against).
//!
//! Run: `cargo run --release --example serve_disaggregated -- [n_requests]`

use std::collections::HashMap;

use revivemoe::config::DeploymentConfig;
use revivemoe::engine::Engine;
use revivemoe::workload;
use revivemoe::Result;

fn main() -> Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(64);
    let cfg = DeploymentConfig::disaggregated_default("artifacts");
    println!(
        "booting MA-disaggregated deployment: {} devices ({} DP attention + {} EP MoE ranks)",
        cfg.n_devices(),
        cfg.n_attn_ranks,
        cfg.n_moe_ranks
    );
    let (mut engine, bd) = Engine::boot(cfg)?;
    println!("{}", bd.render("cached initialization breakdown (Fig 1 analog)"));

    let reqs = workload::gen_mixed(n, 2024)?;
    let mut expected: HashMap<u64, (String, String)> = HashMap::new();
    engine.stats.start();
    for r in reqs {
        let task = r.task.clone();
        let exp = r.expected.clone();
        let id = engine.submit(r)?;
        expected.insert(id, (task, exp));
    }
    let done = engine.run_to_completion(50_000)?;
    engine.stats.stop();

    // per-task answer accuracy (exact match of the generated answer)
    let mut per_task: HashMap<String, (usize, usize)> = HashMap::new();
    for c in &done {
        let (task, exp) = &expected[&c.seq_id];
        let e = per_task.entry(task.clone()).or_default();
        e.1 += 1;
        if workload::decode(&c.output) == *exp {
            e.0 += 1;
        }
    }
    println!("completed {}/{} requests", done.len(), n);
    let mut tasks: Vec<_> = per_task.keys().cloned().collect();
    tasks.sort();
    for t in tasks {
        let (ok, total) = per_task[&t];
        println!("  {t:<8} exact-answer {ok:>2}/{total}");
    }
    println!();
    println!("{}", engine.stats.report());
    println!(
        "sample: {:?} -> {:?}",
        workload::decode(&done[0].prompt),
        workload::decode(&done[0].output)
    );
    engine.shutdown();
    Ok(())
}
