//! Failover demo: a single-NPU failure strikes **mid-generation-step** and
//! ReviveMoE recovers without restarting the instance (paper Fig 3).
//!
//! Timeline printed as it happens:
//!   1. serve traffic on the MA-disaggregated deployment;
//!   2. an attention NPU dies while a decode step is in flight — the step
//!      aborts, leaving uncommitted block-table operations;
//!   3. the heartbeat monitor detects the silent device;
//!   4. ReviveMoE migrates its sequences (prompt ++ decoded tokens), undoes
//!      the partial step from the block-op log, compacts the XCCL domain,
//!      cached-compiles the boundary graphs, and resumes;
//!   5. every request still completes — migrated ones report `migrations=1`.
//!
//! Paper correspondence: Figure 3 (recovery steps 1-7) plus the §3.3
//! log-based block-table undo — the headline claim that a failure is
//! survived *without restarting the serving instance*.
//!
//! Run: `cargo run --release --example failover_demo`

use std::time::Instant;

use revivemoe::cluster::FailureBehavior;
use revivemoe::config::DeploymentConfig;
use revivemoe::engine::Engine;
use revivemoe::recovery::ReviveMoE;
use revivemoe::workload;
use revivemoe::Result;

fn main() -> Result<()> {
    let t0 = Instant::now();
    let stamp = |msg: &str| println!("[{:8.2}s] {msg}", t0.elapsed().as_secs_f64());

    let cfg = DeploymentConfig::disaggregated_default("artifacts");
    stamp("booting 8-device MA-disaggregated deployment ...");
    let (mut engine, _) = Engine::boot(cfg)?;
    stamp("deployment up; submitting 24 requests");

    let mut done = Vec::new();
    for r in workload::gen_mixed(24, 77)? {
        engine.submit(r)?;
    }
    for _ in 0..2 {
        done.extend(engine.step()?);
    }
    stamp(&format!("served 2 steps; {} finished, {} in flight", done.len(), engine.pending()));

    // ---- the failure: a *hung* attention NPU (worst case: no error reply,
    // only the heartbeat can see it) while a step is in flight
    stamp("injecting hardware failure on NPU 1 (attention rank, hung)");
    engine.executors[&1].handle.set_failed(FailureBehavior::Hung);
    match engine.step() {
        Err(e) => stamp(&format!("decode step aborted mid-flight: {e}")),
        Ok(c) => {
            done.extend(c);
            stamp("step raced ahead of the failure; next one will abort");
            if let Err(e) = engine.step() {
                stamp(&format!("decode step aborted: {e}"));
            }
        }
    }

    let ann = engine.detect_failure().expect("heartbeat must flag NPU 1");
    stamp(&format!(
        "failure detected: device {} level {:?} via {}",
        ann.device, ann.level, ann.error_type
    ));

    let report = ReviveMoE::recover(&mut engine, &ann)?;
    stamp(&format!(
        "ReviveMoE recovered in {:.1} ms (migrated {} seqs, undid {} block ops, \
         recompiled {} graphs)",
        report.total().as_secs_f64() * 1e3,
        report.migrated_sequences,
        report.undone_block_ops,
        report.recompiled_graphs
    ));
    println!("{}", report.breakdown.render("recovery breakdown (Fig 5 analog)"));

    done.extend(engine.run_to_completion(50_000)?);
    let migrated = done.iter().filter(|c| c.migrations > 0).count();
    stamp(&format!(
        "all {} requests completed ({} finished on a different rank than they started)",
        done.len(),
        migrated
    ));
    for c in done.iter().filter(|c| c.migrations > 0).take(4) {
        println!(
            "  migrated seq {:>3}: {:?} -> {:?}",
            c.seq_id,
            workload::decode(&c.prompt),
            workload::decode(&c.output)
        );
    }
    engine.shutdown();
    Ok(())
}
