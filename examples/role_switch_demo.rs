//! Role-switch demo (§3.4 / §4.3): a MoE NPU holding the *only* copies of
//! its experts dies. ReviveMoE first keeps the service alive with the
//! degraded expert set (missing-experts masking), then performs the role
//! switch — consuming a DP attention rank, reloading the lost expert
//! weights from disk — restoring full weight integrity. This is the
//! combined strategy §4.3 describes: "a role switch can begin in the
//! background while the system continues inference using the current
//! (possibly incomplete) expert set."
//!
//! Paper correspondence: §3.4 Figure 4's weight-integrity decision (role
//! switch branch) and §4.3 / Figure 5's finding that the switch is
//! dominated by Generator time (expert weights re-read from disk).
//!
//! Run: `cargo run --release --example role_switch_demo`

use revivemoe::cluster::{FailureBehavior, FaultLevel};
use revivemoe::config::DeploymentConfig;
use revivemoe::engine::Engine;
use revivemoe::recovery::{MoeRecoveryKind, ReviveMoE};
use revivemoe::workload;
use revivemoe::Result;

fn main() -> Result<()> {
    // no redundant experts: the failure is guaranteed to lose last copies
    let mut cfg = DeploymentConfig::disaggregated_default("artifacts");
    cfg.redundant_per_rank = 0;
    let (mut engine, _) = Engine::boot(cfg)?;
    println!(
        "deployment: {} DP attention ranks {:?}, {} MoE ranks {:?}, no expert redundancy",
        engine.attn_order.len(),
        engine.attn_order,
        engine.moe_order.len(),
        engine.moe_order
    );

    let mut done = Vec::new();
    for r in workload::gen_mixed(24, 99)? {
        engine.submit(r)?;
    }
    for _ in 0..2 {
        done.extend(engine.step()?);
    }

    // ---- phase 1: fail MoE rank 3 (device 7); policy allows masking, so
    // recovery is instant-ish and the service continues degraded.
    println!("\n=== phase 1: NPU 7 (MoE rank 3) fails; continue with missing experts ===");
    engine.executors[&7].handle.set_failed(FailureBehavior::Erroring);
    engine.plugin.post_fault(7, FaultLevel::L5, FailureBehavior::Erroring, "hbm-uce");
    let ann = engine.detect_failure().unwrap();
    let report = ReviveMoE::recover(&mut engine, &ann)?;
    assert_eq!(report.moe_recovery, Some(MoeRecoveryKind::MissingExperts));
    println!(
        "recovered in {:.1} ms; masked experts {:?} (1/{} of the model)",
        report.total().as_secs_f64() * 1e3,
        report.masked_experts,
        engine.meta.n_experts / report.masked_experts.len().max(1)
    );
    for _ in 0..2 {
        done.extend(engine.step()?); // serving continues, degraded
    }
    println!(
        "serving continues with {} experts masked; {} requests finished so far",
        engine.expert_map.missing_experts().len(),
        done.len()
    );

    // ---- phase 2: the deferred role switch restores weight integrity.
    println!("\n=== phase 2: role switch restores the lost experts from disk ===");
    let t0 = std::time::Instant::now();
    let victim = *engine
        .attn_order
        .iter()
        .min_by_key(|d| {
            engine.executors[d]
                .attn
                .as_ref()
                .map(|a| a.sched.load())
                .unwrap_or(usize::MAX)
        })
        .unwrap();
    println!("victim DP rank: device {victim} (least loaded)");
    // drain + requeue its sequences, then switch
    let seqs = engine.drain_for_migration(victim)?;
    engine.attn_order.retain(|&d| d != victim);
    let n = engine.requeue(seqs)?;
    let meta = engine.meta.clone();
    let slots = engine.expert_map.revive_rank(3)?.to_vec();
    let (dropped, loaded) = {
        let ex = engine.executors.get_mut(&victim).unwrap();
        ex.role_switch_to_moe(3, slots, &meta, &engine.store)?
    };
    engine.moe_order[3] = victim;
    // the switched device needs its MoE graphs + the recreated domain
    let names = revivemoe::executor::artifact_set(
        &engine.executors[&victim],
        &engine.meta,
        &engine.cfg,
    );
    let stats = engine.executors[&victim].compile_set(&engine.arts, &names)?;
    let epoch = engine
        .domains
        .recreate_with_switch(revivemoe::comms::ATTN_EXPERT_DOMAIN, 7, victim)?
        .epoch;
    engine.set_epoch(epoch);
    println!(
        "role switch done in {:.1} ms: migrated {n} seqs, dropped {dropped} attention \
         tensors, loaded {} KiB of expert weights from disk, compiled {} graphs",
        t0.elapsed().as_secs_f64() * 1e3,
        loaded / 1024,
        stats.len()
    );
    assert!(engine.expert_map.missing_experts().is_empty());
    println!(
        "weight integrity restored: DP ranks {:?}, MoE ranks {:?}, no masked experts",
        engine.attn_order, engine.moe_order
    );

    done.extend(engine.run_to_completion(50_000)?);
    println!("\nall {} requests completed across both phases", done.len());
    engine.shutdown();
    Ok(())
}
